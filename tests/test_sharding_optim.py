"""Sharding-rule resolution, optimizers, checkpointing, data pipeline."""
import dataclasses
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.launch.mesh import make_local_mesh
from repro.models import params as PRM, transformer as T
from repro.sharding.rules import MeshRules, PARAM_RULES
from repro.train import checkpoint as CKPT
from repro.train import optimizer as O

# ---------------------------------------------------------------------------
# sharding rules
# ---------------------------------------------------------------------------


class _FakeMesh:
    """Shape-only stand-in so rule resolution is testable on 1 device."""

    def __init__(self, **shape):
        self.shape = shape


def test_divisibility_fallback():
    rules = MeshRules.__new__(MeshRules)
    rules.mesh = _FakeMesh(data=16, model=16)
    rules.param_rules = dict(PARAM_RULES)
    rules.fallbacks = []
    # glm4 kv_heads=2 cannot shard 16-way -> replicated, logged
    spec = rules.spec(("embed", "kv_heads", "head_dim"), (4096, 2, 128),
                      rules.param_rules, "wk")
    assert spec == P("data", None, None)
    assert any("kv_heads=2" in f for f in rules.fallbacks)
    # mlp 13696 doesn't divide... it does (856): sharded
    spec = rules.spec(("embed", "mlp"), (4096, 13696), rules.param_rules)
    assert spec == P("data", "model")


def test_axis_used_once_per_tensor():
    rules = MeshRules.__new__(MeshRules)
    rules.mesh = _FakeMesh(data=4, model=4)
    rules.param_rules = {"a": ("model",), "b": ("model",)}
    rules.fallbacks = []
    spec = rules.spec(("a", "b"), (8, 8), rules.param_rules)
    assert spec == P("model", None)   # second claim on 'model' dropped


def test_param_shardings_resolve_on_local_mesh():
    cfg = get_config("qwen3-14b").reduced()
    mesh = make_local_mesh(1, 1)
    rules = MeshRules(mesh)
    spec = T.model_spec(cfg)
    sds = PRM.abstract_tree(spec, jnp.float32)
    axes = PRM.axes_tree(spec)
    from repro.sharding.rules import param_shardings
    sh = param_shardings(rules, axes, sds)
    leaves = jax.tree.leaves(sh, is_leaf=lambda x: hasattr(x, "spec"))
    assert leaves and all(hasattr(s, "spec") for s in leaves)


# ---------------------------------------------------------------------------
# optimizers
# ---------------------------------------------------------------------------


def _quad_problem():
    target = jnp.asarray(np.random.default_rng(0).normal(size=(6, 4)),
                         jnp.float32)
    params = {"w": jnp.zeros((6, 4)), "b": jnp.zeros((4,))}

    def loss(p):
        return jnp.mean((p["w"] - target) ** 2) + jnp.mean(p["b"] ** 2)
    return params, loss


@pytest.mark.parametrize("name", ["sgdm", "adamw", "adafactor"])
def test_optimizers_descend(name):
    params, loss = _quad_problem()
    opt = O.make_optimizer(name)
    state = opt.init(params)
    l0 = float(loss(params))
    for _ in range(60):
        grads = jax.grad(loss)(params)
        params, state = opt.update(grads, state, params, jnp.float32(0.05))
    assert float(loss(params)) < 0.25 * l0


def test_adafactor_state_is_factored():
    params = {"w": jnp.zeros((64, 32)), "b": jnp.zeros((32,))}
    opt = O.make_optimizer("adafactor")
    state = opt.init(params)
    assert state["slots"]["w"]["v_row"].shape == (64,)
    assert state["slots"]["w"]["v_col"].shape == (32,)
    assert state["slots"]["b"]["v"].shape == (32,)
    # axes follow the same factoring
    ax = opt.state_axes({"w": ("embed", "mlp"), "b": ("mlp",)})
    assert ax["slots"]["w"]["v_row"] == ("embed",)
    assert ax["slots"]["w"]["v_col"] == ("mlp",)


def test_adamw_state_axes_mirror_params():
    opt = O.make_optimizer("adamw")
    ax = opt.state_axes({"w": ("embed", "mlp")})
    assert ax["m"]["w"] == ("embed", "mlp")


# ---------------------------------------------------------------------------
# checkpoint
# ---------------------------------------------------------------------------


def test_checkpoint_roundtrip():
    cfg = get_config("h2o-danube-1.8b").reduced()
    spec = T.model_spec(cfg)
    params = PRM.init_tree(spec, jax.random.key(0), jnp.float32)
    opt = O.make_optimizer("adamw")
    state = opt.init(params)
    with tempfile.TemporaryDirectory() as d:
        CKPT.save(d, 7, params, state)
        assert CKPT.latest_step(d) == 7
        p2, s2 = CKPT.restore(d, 7, params, state)
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert int(s2["count"]) == 0


def test_checkpoint_bf16_roundtrip():
    params = {"w": jnp.asarray([[1.5, -2.25]], jnp.bfloat16)}
    with tempfile.TemporaryDirectory() as d:
        CKPT.save(d, 1, params)
        p2, _ = CKPT.restore(d, 1, params)
        assert p2["w"].dtype == jnp.bfloat16
        np.testing.assert_array_equal(np.asarray(p2["w"], np.float32),
                                      np.asarray(params["w"], np.float32))


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------


def test_synthetic_recsys_matches_table1_density():
    from repro.configs.vfl_recsys import VFLRecsysConfig
    from repro.data.synthetic import make_recsys_silos
    cfg = VFLRecsysConfig().reduced()
    data = make_recsys_silos(cfg, seed=0)
    density = data.labels.mean()
    expect = cfg.n_interactions / (cfg.n_users * cfg.n_items)
    assert abs(density - min(expect, 1.0)) < 0.05
    assert data.features.shape == (cfg.n_users, cfg.n_other_features)
    assert len(data.member_ids[0]) == int(cfg.id_overlap * cfg.n_users)


def test_lm_batches_are_deterministic():
    from repro.data.synthetic import make_lm_batches
    a = list(make_lm_batches(100, 2, 16, 3, seed=5))
    b = list(make_lm_batches(100, 2, 16, 3, seed=5))
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x["tokens"], y["tokens"])


# ---------------------------------------------------------------------------
# §Perf policy
# ---------------------------------------------------------------------------


def test_recommended_opts_policy():
    from repro.configs import SHAPES, get_config
    from repro.launch.dryrun import recommended_opts
    # MoE with small experts -> grouped dispatch + DP experts
    assert recommended_opts(get_config("granite-moe-3b-a800m"),
                            SHAPES["train_4k"]) == "moegroup,moedp"
    # MoE with big experts keeps EP
    assert recommended_opts(get_config("jamba-1.5-large-398b"),
                            SHAPES["train_4k"]) == "moegroup"
    # dense decode: TP-only weights + partial-softmax
    assert recommended_opts(get_config("glm4-9b"),
                            SHAPES["decode_32k"]) \
        == "noweightfsdp,decodeps"
    # batch=1 decode must NOT use the partial-softmax path
    assert "decodeps" not in recommended_opts(
        get_config("h2o-danube-1.8b"), SHAPES["long_500k"])
    # dense train: baseline is the best known config
    assert recommended_opts(get_config("qwen3-14b"),
                            SHAPES["train_4k"]) == ""


def test_recsys_metrics():
    from repro.train.evals import auc, ndcg_at_k, precision_at_k
    rng = np.random.default_rng(0)
    labels = (rng.random((50, 10)) < 0.3).astype(np.float64)
    perfect = labels + rng.random((50, 10)) * 0.01
    rand = rng.random((50, 10))
    assert auc(perfect, labels) > 0.99
    assert 0.4 < auc(rand, labels) < 0.6
    assert precision_at_k(perfect, labels, 3) >= precision_at_k(
        rand, labels, 3)
    assert ndcg_at_k(perfect, labels, 5) > 0.99
    # antiperfect scores -> worst ranking
    assert auc(-perfect, labels) < 0.01
