"""Communication layer + crypto substrate tests, incl. hypothesis
property tests on the system invariants: codec roundtrip, Paillier
homomorphism, PSI correctness, secure-agg mask cancellation."""
import threading

import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.comm import codec
from repro.comm.local import ThreadBus
from repro.comm.sock import SocketCommunicator, local_addresses
from repro.core import he, psi, secure_agg

# ---------------------------------------------------------------------------
# codec
# ---------------------------------------------------------------------------

_DTYPES = [np.float32, np.float64, np.int32, np.int64, np.uint8, np.bool_]


@settings(max_examples=40, deadline=None)
@given(st.integers(0, 5), st.integers(1, 4), st.integers(0, len(_DTYPES) - 1),
       st.integers(0, 2**31 - 1))
def test_codec_roundtrip_property(rank_extra, dim, dt_idx, seed):
    rng = np.random.default_rng(seed)
    shape = tuple(rng.integers(1, 5, size=min(rank_extra, 3)))
    dt = _DTYPES[dt_idx]
    if dt == np.bool_:
        arr = rng.random(shape) > 0.5
    else:
        arr = (rng.random(shape) * 100).astype(dt)
    blob = codec.encode({"x": arr, "y": np.arange(dim, dtype=np.int32)},
                        {"tag": "t"})
    out, meta = codec.decode(blob)
    assert meta["tag"] == "t"
    np.testing.assert_array_equal(out["x"], arr)


def test_codec_bytes_tensors_preserve_nul():
    """Binary strings with trailing NULs survive (the S-dtype trap)."""
    raw = np.frombuffer(b"\x01\x02\x00\x00" * 3, np.uint8).reshape(3, 4)
    out, _ = codec.decode(codec.encode({"b": raw}))
    np.testing.assert_array_equal(out["b"], raw)
    s = np.array([b"ab\x00\x00", b"\x00cd\x00"], dtype="S4")
    out, _ = codec.decode(codec.encode({"s": s}))
    assert out["s"].tobytes() == s.tobytes()


def test_codec_header_is_safetensors_layout():
    blob = codec.encode({"x": np.zeros((2, 2), np.float32)})
    import json
    import struct
    (hlen,) = struct.unpack_from("<Q", blob, 0)
    header = json.loads(blob[8:8 + hlen])
    assert header["x"]["dtype"] == "F32"
    assert header["x"]["shape"] == [2, 2]
    assert header["x"]["data_offsets"] == [0, 16]


# ---------------------------------------------------------------------------
# communicators
# ---------------------------------------------------------------------------


def _pingpong(comm_a, comm_b):
    out = {}

    def a():
        comm_a.send("b", "ping", {"x": np.arange(5, dtype=np.float32)})
        out["a"] = comm_a.recv("b", "pong").tensor("x")

    def b():
        m = comm_b.recv("a", "ping")
        comm_b.send("a", "pong", {"x": m.tensor("x") * 2})

    ta, tb = threading.Thread(target=a), threading.Thread(target=b)
    ta.start(); tb.start(); ta.join(30); tb.join(30)
    return out["a"]


def test_thread_communicator():
    bus = ThreadBus(["a", "b"])
    got = _pingpong(bus.communicator("a"), bus.communicator("b"))
    np.testing.assert_array_equal(got, np.arange(5, dtype=np.float32) * 2)


def test_socket_communicator():
    addrs = local_addresses(["a", "b"])
    ca, cb = SocketCommunicator("a", addrs), SocketCommunicator("b", addrs)
    try:
        got = _pingpong(ca, cb)
        np.testing.assert_array_equal(got,
                                      np.arange(5, dtype=np.float32) * 2)
        assert ca.stats.sent_messages == 1
        assert ca.stats.sent_bytes > 0
    finally:
        ca.close(); cb.close()


def test_out_of_order_tags():
    bus = ThreadBus(["a", "b"])
    ca, cb = bus.communicator("a"), bus.communicator("b")
    ca.send("b", "t1", {"x": np.array([1.0])})
    ca.send("b", "t2", {"x": np.array([2.0])})
    assert cb.recv("a", "t2").tensor("x")[0] == 2.0   # later tag first
    assert cb.recv("a", "t1").tensor("x")[0] == 1.0


# ---------------------------------------------------------------------------
# Paillier
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def keys():
    return he.keygen(256)


@settings(max_examples=15, deadline=None)
@given(st.integers(-10**6, 10**6), st.integers(-10**6, 10**6))
def test_paillier_additive_homomorphism(a, b):
    pub, priv = _KEYS
    ca, cb = pub.encrypt_int(a), pub.encrypt_int(b)
    assert priv.decrypt_int(pub.add(ca, cb)) == a + b


@settings(max_examples=15, deadline=None)
@given(st.integers(-10**4, 10**4), st.integers(-10**3, 10**3))
def test_paillier_scalar_mult(a, k):
    pub, priv = _KEYS
    assert priv.decrypt_int(pub.mul_scalar(pub.encrypt_int(a), k)) == a * k


_KEYS = he.keygen(256)


def test_paillier_vector_roundtrip(keys):
    pub, priv = keys
    x = np.array([0.5, -1.25, 3.75, 0.0])
    c = he.encrypt_vector(pub, x)
    np.testing.assert_allclose(he.decrypt_vector(priv, c), x, atol=1e-8)


def test_paillier_encrypted_matvec(keys):
    pub, priv = keys
    rng = np.random.default_rng(0)
    X = rng.normal(size=(6, 3))
    r = rng.normal(size=(6,))
    enc_r = he.encrypt_vector(pub, r)
    enc_g = he.matvec_cipher(pub, X, enc_r)
    flat = [priv.decrypt_int(int(v)) for v in enc_g]
    g = he.decode_fixed(flat, (3,), scale_bits=2 * he.SCALE_BITS)
    np.testing.assert_allclose(g, X.T @ r, atol=1e-7)


# ---------------------------------------------------------------------------
# PSI
# ---------------------------------------------------------------------------


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(0, 40), st.integers(0, 40),
       st.integers(0, 40))
def test_dh_psi_property(seed, n_common, n_a, n_b):
    common = [f"c{i}" for i in range(n_common)]
    only_a = [f"a{i}" for i in range(n_a)]
    only_b = [f"b{i}" for i in range(n_b)]
    inter, _ = psi.dh_psi(common + only_a, common + only_b)
    assert inter == sorted(common)


def test_salted_hash_matches_dh():
    a = [f"u{i}" for i in range(50)]
    b = [f"u{i}" for i in range(25, 70)]
    assert psi.salted_hash_intersection(a, b, "s") == psi.dh_psi(a, b)[0]


# ---------------------------------------------------------------------------
# secure aggregation
# ---------------------------------------------------------------------------


@settings(max_examples=10, deadline=None)
@given(st.integers(2, 5), st.integers(0, 2**31 - 1))
def test_mask_cancellation_property(n_parties, seed):
    import jax
    import jax.numpy as jnp
    key = jax.random.key(seed)
    xs = [jax.random.normal(jax.random.fold_in(key, 100 + i), (4, 3))
          for i in range(n_parties)]
    masked = [secure_agg.mask_contribution(key, i, n_parties, x)
              for i, x in enumerate(xs)]
    # each masked tensor differs from its plaintext...
    for x, m in zip(xs, masked):
        assert float(jnp.abs(x - m).max()) > 1e-3
    # ...but the aggregate is exact (identical values cancel)
    np.testing.assert_allclose(
        np.asarray(secure_agg.aggregate(masked)),
        np.asarray(secure_agg.aggregate(xs)), atol=1e-4)
