"""Pipelined HE decryption (DESIGN.md §10): the arbiter decrypt worker
pool (bit-identical plaintexts, order-preserving reassembly, attributed
worker-crash propagation), streamed ciphertext rounds, the deferred
gradient apply at pipeline depth >= 2, and key-sharded multi-arbiter
decryption — unit level plus end-to-end ``logreg_he`` runs and a
two-arbiter cluster spec."""
import time

import numpy as np
import pytest

from repro.core import he
from repro.core.he.decrypt_pool import DecryptWorkerError
from repro.core.party import VFLJob, run_vfl
from repro.core.protocols.base import MasterData, MemberData, VFLConfig
from repro.launch.cluster import load_spec
from repro.train.evals import auc

_KEYS = he.keygen(256)


# ---------------------------------------------------------------------------
# decrypt pool: correctness
# ---------------------------------------------------------------------------


def test_pooled_decrypt_bit_identical_to_serial():
    pub, priv = _KEYS
    vals = [int(v) for v in
            np.random.default_rng(0).integers(-2**40, 2**40, 64)]
    cts = [pub.encrypt_int(v) for v in vals]
    serial = [priv.decrypt_int(c) for c in cts]
    with he.DecryptPool(priv, workers=2) as pool:
        pooled = pool.decrypt_many(cts, chunk=16)
        stats = pool.stats()
    assert pooled == serial == vals
    assert stats["chunks"] == 4 and stats["values"] == 64
    assert stats["workers"] == 2 and stats["max_busy"] >= 1


@pytest.mark.parametrize("workers", [0, 2])
def test_session_reassembles_in_index_order(workers):
    """Chunks submitted in ANY index order (late wire arrival) come
    back concatenated by index, not by completion or submission time."""
    pub, priv = _KEYS
    vals = list(range(-30, 30))
    chunks = [vals[i:i + 10] for i in range(0, 60, 10)]
    enc = [[pub.encrypt_int(v) for v in ch] for ch in chunks]
    with he.DecryptPool(priv, workers=workers) as pool:
        sess = pool.session()
        for idx in [3, 0, 5, 1, 4, 2]:          # deliberately shuffled
            sess.submit(idx, enc[idx])
        assert sess.gather() == vals


def test_decrypt_vector_routes_through_pool():
    pub, priv = _KEYS
    arr = np.array([[1.5, -2.25, 0.0], [3.0, 0.125, -7.5]])
    enc = he.encrypt_vector(pub, arr)
    serial = he.decrypt_vector(priv, enc)
    with he.DecryptPool(priv, workers=2) as pool:
        pooled = he.decrypt_vector(priv, enc, pool=pool, chunk=2)
    assert np.array_equal(serial, pooled)
    np.testing.assert_allclose(pooled, arr)


# ---------------------------------------------------------------------------
# decrypt pool: failure attribution (no hangs)
# ---------------------------------------------------------------------------


def test_dead_worker_raises_attributed_error_fast():
    """A worker killed mid-round must surface as DecryptWorkerError
    naming the worker, well before the gather timeout — never a hang."""
    pub, priv = _KEYS
    with he.DecryptPool(priv, workers=1, timeout_s=30.0) as pool:
        pool._procs[0].kill()
        pool._procs[0].join(timeout=10)
        sess = pool.session()
        sess.submit(0, [pub.encrypt_int(7)])
        t0 = time.monotonic()
        with pytest.raises(DecryptWorkerError, match=r"worker #0 .*died"):
            sess.gather()
        assert time.monotonic() - t0 < 10.0     # liveness check, not timeout


def test_worker_reported_failure_is_attributed_and_survivable():
    """A worker that hits an exception reports it (attributed to the
    chunk) without dying — the pool stays usable for the next round."""
    pub, priv = _KEYS
    with he.DecryptPool(priv, workers=1) as pool:
        sess = pool.session()
        # bypass submit()'s int coercion to hand the worker a ciphertext
        # it cannot pow() — the shape of a corrupt frame off the wire
        pool._inflight += 1
        pool._task_q.put((sess._sid, 0, ["not-a-ciphertext"]))
        sess._submitted += 1
        with pytest.raises(DecryptWorkerError, match=r"worker #0 failed"):
            sess.gather()
        assert pool._procs[0].is_alive()
        sess2 = pool.session()
        sess2.submit(0, [pub.encrypt_int(-9)])
        assert sess2.gather() == [-9]


def test_inline_gather_detects_missing_chunks():
    _, priv = _KEYS
    pool = he.DecryptPool(priv, workers=0)
    sess = pool.session()
    with pytest.raises(DecryptWorkerError, match="never submitted"):
        sess.gather(n=2)


# ---------------------------------------------------------------------------
# end-to-end logreg_he
# ---------------------------------------------------------------------------

_N, _D = 256, 12


def _dataset():
    rng = np.random.default_rng(0)
    x = rng.normal(size=(_N, _D))
    w = rng.normal(size=(_D, 1))
    y = (1.0 / (1.0 + np.exp(-(x @ w))) > 0.5).astype(np.float64)
    ids = np.array([f"id{i}" for i in range(_N)])
    cols = np.array_split(np.arange(_D), 2)
    return (x, y, MasterData(ids=ids, y=y, x=None),
            [MemberData(ids=ids, x=x[:, c]) for c in cols], cols)


def _run(master, members, **kw):
    cfg = VFLConfig(protocol="logreg_he", epochs=2, batch_size=64,
                    lr=0.5, use_psi=False, he_bits=128, seed=3, **kw)
    return run_vfl(cfg, master, members, mode="thread")


def _auc_of(res, x, y, cols):
    z = sum(x[:, c] @ res[f"member{j}"]["w"]
            for j, c in enumerate(cols))
    return auc(1.0 / (1.0 + np.exp(-z)), y)


def test_streamed_pooled_depth1_bit_identical_to_serial():
    """All pipeline knobs on at depth 1 must reproduce the serial
    decrypt path EXACTLY — same plaintexts, same float ops, same
    weights — because chunking/pooling only re-partitions the work."""
    x, y, master, members, cols = _dataset()
    base = _run(master, members)
    piped = _run(master, members, he_stream_chunks=3,
                 he_decrypt_workers=2)
    for j in range(2):
        assert np.array_equal(base[f"member{j}"]["w"],
                              piped[f"member{j}"]["w"])
    assert base["master"]["w_master"] is None \
        and piped["master"]["w_master"] is None
    # instrumentation surfaced in the result dicts
    dp = piped["arbiter"]["decrypt_pool"]
    assert dp["workers"] == 2 and dp["chunks"] > base[
        "arbiter"]["decrypt_pool"]["chunks"]
    rp = piped["master"]["rand_pool"]
    # one take per encrypted residual: 2 epochs x 4 batches x 64 rows
    assert rp["hits"] + rp["fallbacks"] == 2 * _N
    assert rp["generated"] >= rp["hits"]          # filler may overshoot


def test_depth2_deferred_apply_converges_same():
    """Depth-2 pipelining trades one round of gradient staleness for
    overlap; the fit must land on the same model quality (and the
    deferred final gradient must be flushed, not dropped)."""
    x, y, master, members, cols = _dataset()
    d1 = _run(master, members)
    d2 = _run(master, members, pipeline_depth=2)
    a1, a2 = _auc_of(d1, x, y, cols), _auc_of(d2, x, y, cols)
    assert a1 > 0.85                              # the fit actually works
    np.testing.assert_allclose(a2, a1, rtol=2e-2)
    # staleness is real: weights differ, quality does not
    assert not np.array_equal(d1["member0"]["w"], d2["member0"]["w"])


def test_two_arbiter_key_sharding_matches_single():
    """Key-sharded decryption re-partitions exact integer arithmetic:
    two arbiters with independent keypairs must reproduce the
    single-arbiter model (acceptance: AUC within rtol 1e-4)."""
    x, y, master, members, cols = _dataset()
    one = _run(master, members)
    two = _run(master, members, n_arbiters=2)
    assert sorted(k for k in two if k.startswith("arbiter")) == \
        ["arbiter", "arbiter1"]
    for j in range(2):
        np.testing.assert_allclose(two[f"member{j}"]["w"],
                                   one[f"member{j}"]["w"],
                                   rtol=1e-9, atol=0)
    np.testing.assert_allclose(_auc_of(two, x, y, cols),
                               _auc_of(one, x, y, cols), rtol=1e-4)
    # each arbiter decrypted only its slice, and both did real work
    for arb in ("arbiter", "arbiter1"):
        assert two[arb]["decrypted_values"] > 0


# ---------------------------------------------------------------------------
# cluster spec: key-sharded deployment
# ---------------------------------------------------------------------------


def _sharded_spec_dict():
    agents = ["master", "member0", "member1", "arbiter", "arbiter1"]
    return {
        "protocol": {"name": "logreg_he", "epochs": 2, "batch_size": 64,
                     "lr": 0.5, "seed": 0, "use_psi": False,
                     "he_bits": 128, "n_arbiters": 2,
                     "pipeline_depth": 2, "he_stream_chunks": 2},
        "run": {"phases": ["fit"]},
        "data": {"provider":
                 "repro.launch.cluster:logreg_he_demo_data", "seed": 0},
        "comm": {"framing": "sock", "timeout": 60.0},
        "agents": {a: f"127.0.0.1:{18800 + i}"
                   for i, a in enumerate(agents)},
        "hosts": {"alpha": {"control": "127.0.0.1:18890",
                            "agents": agents}},
    }


def test_sharded_cluster_spec_validates():
    spec = load_spec(_sharded_spec_dict())
    spec.validate()
    assert spec.world() == ["master", "member0", "member1",
                            "arbiter", "arbiter1"]
    assert spec.cfg.n_arbiters == 2
    # dropping the second arbiter from [agents] is a world mismatch
    bad = _sharded_spec_dict()
    del bad["agents"]["arbiter1"]
    with pytest.raises(ValueError, match="exactly the protocol"):
        load_spec(bad).validate()


def test_sharded_spec_runs_in_process():
    """The committed two-arbiter deployment shape trains end-to-end via
    VFLJob.from_spec — the same path `repro.launch.cluster` drives."""
    spec = load_spec(_sharded_spec_dict())
    job = VFLJob.from_spec(spec, mode="thread")
    fit = job.fit()
    res = job.shutdown()
    losses = [h["loss"] for h in fit["history"]]
    assert losses[-1] < losses[0]
    for arb in ("arbiter", "arbiter1"):
        assert res[arb]["decrypted_values"] > 0
        assert "decrypt_pool" in res[arb]


def test_committed_sharded_example_spec_loads():
    import pathlib
    spec = load_spec(pathlib.Path(__file__).resolve().parents[1]
                     / "examples" / "cluster" / "logreg_he_sharded.toml")
    spec.validate()
    assert spec.cfg.n_arbiters == 2 and spec.cfg.he_decrypt_workers == 2
    assert spec.world()[-2:] == ["arbiter", "arbiter1"]
