"""Multi-device correctness of the §Perf decode levers (subprocess keeps
this test process single-device) + process-mode VFL equivalence."""
import pathlib
import subprocess
import sys
import textwrap

import numpy as np
import pytest

SRC = str(pathlib.Path(__file__).resolve().parents[1] / "src")


@pytest.mark.slow
def test_partial_softmax_decode_matches_baseline():
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import dataclasses
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import get_config
        from repro.models import params as PRM, transformer as T
        from repro.launch import specs as S
        from repro.sharding.rules import MeshRules
        from repro.configs.base import InputShape

        cfg = get_config("glm4-9b").reduced()
        cfg = dataclasses.replace(cfg, n_kv_heads=2, n_heads=4, head_dim=32)
        from repro.launch.mesh import make_local_mesh
        mesh = make_local_mesh(2, 4)    # AxisType-compat across jax versions
        rules = MeshRules(mesh)
        spec = T.model_spec(cfg)
        params = PRM.init_tree(spec, jax.random.key(0), jnp.float32)

        b, s = 4, 16
        toks = jax.random.randint(jax.random.key(1), (b, s), 0, cfg.vocab)

        def run(use_ps):
            c = dataclasses.replace(cfg, decode_partial_softmax=use_ps)
            from repro.sharding.rules import use_rules
            cache = T.init_cache(c, b, s, jnp.float32)
            if use_ps:
                # shard cache seq over model like the dry-run does
                ax = S.cache_axes(c)
                cache = jax.tree.map(
                    lambda x, a: jax.device_put(
                        x, NamedSharding(mesh, rules.act_spec(a, x.shape))),
                    cache, ax,
                    is_leaf=lambda x: hasattr(x, "shape"))

            def step_fn(p, t, ch, i):
                with use_rules(rules if use_ps else None):
                    return T.decode_step(c, p, t, ch, i, None, jnp.float32)

            step = jax.jit(step_fn)
            outs = []
            with mesh:
                for i in range(s):
                    logits, cache = step(params, toks[:, i:i+1], cache, i)
                    outs.append(np.asarray(logits[:, 0]))
            return np.stack(outs, 1)

        base = run(False)
        shard = run(True)
        err = np.abs(base - shard).max()
        assert err < 2e-3, err
        print("SHARDED_DECODE_OK", err)
    """)
    out = subprocess.run([sys.executable, "-c", code],
                         env={"PYTHONPATH": SRC, "PATH": "/usr/bin:/bin",
                              "HOME": "/root", "JAX_PLATFORMS": "cpu"},
                         capture_output=True, text=True, timeout=560)
    assert "SHARDED_DECODE_OK" in out.stdout, out.stderr[-3000:]


def test_process_mode_equivalence():
    """The paper's third execution mode (multiprocessing) produces the
    same training trace as thread mode."""
    from repro.core.party import run_vfl
    from repro.core.protocols.base import VFLConfig
    from repro.data.vertical import vertical_partition
    rng = np.random.default_rng(0)
    n, d = 96, 10
    x = rng.normal(size=(n, d))
    y = x @ rng.normal(size=(d, 2)) * 0.3
    ids = [f"u{i:05d}" for i in range(n)]
    master, members = vertical_partition(ids, x, y, widths=[4], seed=1)
    cfg = VFLConfig(protocol="linreg", epochs=1, batch_size=32, lr=0.1,
                    use_psi=False)
    ref = run_vfl(cfg, master, members, mode="thread")
    got = run_vfl(cfg, master, members, mode="process")
    np.testing.assert_allclose(
        [h["loss"] for h in got["master"]["history"]],
        [h["loss"] for h in ref["master"]["history"]], rtol=0, atol=0)
