"""Per-kernel allclose vs the pure-jnp oracle, sweeping shapes + dtypes
(interpret mode executes the kernel body on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


def _rand(key, shape, dtype):
    x = jax.random.normal(key, shape, jnp.float32)
    return x.astype(dtype)


ATT_CASES = [
    # b, h, kvh, s, dh, causal, window, dtype
    (2, 4, 2, 256, 64, True, 0, jnp.float32),
    (1, 4, 4, 128, 32, True, 64, jnp.float32),
    (2, 2, 1, 128, 128, False, 0, jnp.float32),
    (1, 8, 2, 512, 64, True, 128, jnp.float32),
    (1, 2, 2, 256, 64, True, 0, jnp.bfloat16),
]


@pytest.mark.parametrize("b,h,kvh,s,dh,causal,window,dtype", ATT_CASES)
def test_flash_attention_vs_ref(b, h, kvh, s, dh, causal, window, dtype):
    ks = jax.random.split(jax.random.key(hash((b, h, s)) % 2**31), 3)
    q = _rand(ks[0], (b, h, s, dh), dtype)
    k = _rand(ks[1], (b, kvh, s, dh), dtype)
    v = _rand(ks[2], (b, kvh, s, dh), dtype)
    out = ops.flash_attention(q, k, v, causal=causal, window=window,
                              interpret=True)
    expect = ref.attention_ref(q, k, v, causal=causal, window=window)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32),
                               atol=tol, rtol=tol)


@pytest.mark.parametrize("block_q,block_k", [(64, 64), (128, 32)])
def test_flash_attention_block_shapes(block_q, block_k):
    ks = jax.random.split(jax.random.key(7), 3)
    q = _rand(ks[0], (1, 2, 256, 64), jnp.float32)
    k = _rand(ks[1], (1, 2, 256, 64), jnp.float32)
    v = _rand(ks[2], (1, 2, 256, 64), jnp.float32)
    out = ops.flash_attention(q, k, v, block_q=block_q, block_k=block_k,
                              interpret=True)
    expect = ref.attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               atol=2e-5, rtol=2e-5)


SSM_CASES = [
    (1, 64, 32, 8, jnp.float32),
    (2, 128, 64, 16, jnp.float32),
    (1, 256, 32, 4, jnp.bfloat16),
]


@pytest.mark.parametrize("b,s,di,n,dtype", SSM_CASES)
def test_selective_scan_vs_ref(b, s, di, n, dtype):
    ks = jax.random.split(jax.random.key(s + di), 5)
    dt = jax.nn.softplus(_rand(ks[0], (b, s, di), dtype)) * 0.1
    bm = _rand(ks[1], (b, s, n), dtype)
    cm = _rand(ks[2], (b, s, n), dtype)
    u = _rand(ks[3], (b, s, di), dtype)
    a = -jnp.exp(_rand(ks[4], (di, n), jnp.float32) * 0.5)
    y1, h1 = ops.selective_scan(dt, bm, cm, u, a, block_d=32, chunk=32,
                                interpret=True)
    y2, h2 = ref.selective_scan_ref(dt, bm, cm, u, a)
    tol = 3e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               atol=tol, rtol=tol)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2),
                               atol=tol, rtol=tol)


WKV_CASES = [
    (1, 2, 64, 32, jnp.float32),
    (2, 4, 128, 64, jnp.float32),
    (1, 2, 128, 32, jnp.bfloat16),
]


@pytest.mark.parametrize("b,h,s,dh,dtype", WKV_CASES)
def test_rwkv6_wkv_vs_ref(b, h, s, dh, dtype):
    ks = jax.random.split(jax.random.key(h * s), 5)
    r = _rand(ks[0], (b, h, s, dh), dtype)
    k = _rand(ks[1], (b, h, s, dh), dtype)
    v = _rand(ks[2], (b, h, s, dh), dtype)
    w = (jax.nn.sigmoid(_rand(ks[3], (b, h, s, dh), jnp.float32)) * 0.5
         + 0.45).astype(dtype)
    u = _rand(ks[4], (h, dh), jnp.float32) * 0.3
    y1, s1 = ops.rwkv6_wkv(r, k, v, w, u, chunk=32, interpret=True)
    y2, s2 = ref.rwkv6_ref(r, k, v, w, u)
    tol = 5e-2 if dtype == jnp.bfloat16 else 5e-5
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               atol=tol, rtol=tol)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                               atol=tol, rtol=tol)


GMM_CASES = [
    (4, 128, 64, 96, jnp.float32),
    (8, 256, 128, 128, jnp.float32),
    (2, 128, 128, 64, jnp.bfloat16),
]


@pytest.mark.parametrize("e,c,d,f,dtype", GMM_CASES)
def test_moe_gmm_vs_ref(e, c, d, f, dtype):
    x = _rand(jax.random.key(e * c), (e, c, d), dtype)
    w = _rand(jax.random.key(d * f), (e, d, f), dtype)
    out = ops.moe_gmm(x, w, block_c=64, block_f=min(128, f),
                      block_d=min(64, d), interpret=True)
    expect = ref.gmm_ref(x, w)
    tol = 5e-2 if dtype == jnp.bfloat16 else 2e-4
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32),
                               atol=tol, rtol=tol)


def test_kernels_match_model_paths():
    """The model's jnp attention equals the kernel on the same inputs
    (layout transposed) — the integration contract."""
    from repro.models.attention import attend
    ks = jax.random.split(jax.random.key(11), 3)
    b, h, kvh, s, dh = 1, 4, 2, 128, 64
    q = jax.random.normal(ks[0], (b, s, h, dh))
    k = jax.random.normal(ks[1], (b, s, kvh, dh))
    v = jax.random.normal(ks[2], (b, s, kvh, dh))
    pos = jnp.arange(s)
    model_out = attend(q, k, v, pos, pos, window=0, causal=True)
    kernel_out = ops.flash_attention(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3), causal=True, interpret=True)
    np.testing.assert_allclose(np.asarray(model_out),
                               np.asarray(kernel_out.transpose(0, 2, 1, 3)),
                               atol=2e-5, rtol=2e-5)
