"""Serve-phase load probe for scripts/ci_cluster.py.

Launched as a subprocess with PYTHONPATH=src (the CI cluster driver
itself stays stdlib-only): connects to the cluster's serve frontend,
fires ``--requests`` concurrent queries that together cover every
matched row exactly once, and writes a JSON verdict with the served
AUC (computed against the locally rebuilt quickstart labels — the
agreed sample order is the sorted id intersection, a wire-schema
contract) plus latency quantiles. The CI driver compares the served
AUC against the cluster's own offline ``evaluate`` summary.

  PYTHONPATH=src python scripts/ci_serve_probe.py \\
      --port 18080 --requests 200 --out probe.json
"""
from __future__ import annotations

import argparse
import json
import queue
import sys
import threading
import time

import numpy as np


def _percentile(lat, q):
    s = sorted(lat)
    return s[min(len(s) - 1, int(q * len(s)))] if s else 0.0


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, required=True)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--requests", type=int, default=200)
    ap.add_argument("--threads", type=int, default=16)
    ap.add_argument("--out", required=True)
    ap.add_argument("--connect-timeout", type=float, default=480.0)
    args = ap.parse_args()

    from repro.launch.cluster import quickstart_data
    from repro.serve.federated import ServeClient
    from repro.train.evals import recsys_report

    # rebuild the labels in the cluster's row order: the agreed sample
    # order is sorted(common ids) (comm/schema "match/order")
    md = quickstart_data("master", seed=args.seed)
    mb = quickstart_data("member0", seed=args.seed)
    order = sorted(set(md.ids) & set(mb.ids))
    pos = {i: k for k, i in enumerate(md.ids)}
    y = np.asarray(md.y)[[pos[o] for o in order]]
    n = len(order)

    # wait for the frontend (the cluster is still fitting when the CI
    # driver starts this probe)
    deadline = time.monotonic() + args.connect_timeout
    while True:
        c = ServeClient(args.host, args.port, timeout=60.0)
        try:
            c.query(np.array([0]))
            c.close()
            break
        except OSError:
            c.close()
            if time.monotonic() > deadline:
                print("probe: frontend never came up", file=sys.stderr)
                return 1
            time.sleep(0.5)

    # --requests concurrent queries covering rows 0..n-1 exactly once
    chunks = np.array_split(np.arange(n, dtype=np.int64),
                            args.requests)
    work: "queue.Queue" = queue.Queue()
    for qid, rows in enumerate(chunks):
        work.put((qid, rows))
    scores = [None] * len(chunks)
    lat, errs = [], []
    lock = threading.Lock()

    def run() -> None:
        cli = ServeClient(args.host, args.port, timeout=60.0)
        try:
            while True:
                try:
                    qid, rows = work.get_nowait()
                except queue.Empty:
                    return
                t0 = time.perf_counter()
                try:
                    s = cli.query(rows)
                except Exception as e:          # noqa: BLE001
                    with lock:
                        errs.append(f"query {qid}: {e!r}")
                    continue
                dt = time.perf_counter() - t0
                with lock:
                    scores[qid] = np.asarray(s)
                    lat.append(dt)
        finally:
            cli.close()

    t0 = time.perf_counter()
    ts = [threading.Thread(target=run) for _ in range(args.threads)]
    for t in ts:
        t.start()
    for t in ts:
        t.join(600)
    wall = time.perf_counter() - t0

    if errs or any(s is None for s in scores):
        print(f"probe: {len(errs)} failed queries: {errs[:5]}",
              file=sys.stderr)
        return 1

    served = np.concatenate(scores, axis=0)
    report = recsys_report(served, y, k=5)
    with ServeClient(args.host, args.port, timeout=60.0) as cli:
        serve_stats = cli.stats()

    out = {
        "rows": n,
        "requests": len(chunks),
        "qps": len(chunks) / wall,
        "p50_ms": _percentile(lat, 0.50) * 1e3,
        "p99_ms": _percentile(lat, 0.99) * 1e3,
        "auc": float(report["auc"]),
        "serve_stats": serve_stats,
    }
    with open(args.out, "w") as f:
        json.dump(out, f, indent=1)
    print(f"probe: {json.dumps(out)[:400]}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
