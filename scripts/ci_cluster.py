"""CI cluster job: drive the real launcher CLI end-to-end.

Two rounds, both as two launcher invocations ("hosts") on localhost
sharing one spec file, TLS on, gRPC framing (i.e. the TLS'd
``grpc_proc`` deployment shape):

1. **Convergence** — the quickstart split-NN cluster spec must run to
   completion on both launchers (exit 0) with the training loss
   strictly decreasing and the federated evaluate reporting a sane
   AUC.
2. **Chaos** — relaunch a long link-shaped run, SIGKILL one member
   mid-epoch, and require BOTH launchers to exit non-zero within 30
   seconds naming the dead member (no hang until a transport timeout).

Exits non-zero on the first violated assertion, printing both
launchers' output. Stdlib only.

  PYTHONPATH=src python scripts/ci_cluster.py [--workdir DIR]
"""
from __future__ import annotations

import argparse
import json
import os
import pathlib
import signal
import socket
import subprocess
import sys
import tempfile
import time

REPO = pathlib.Path(__file__).resolve().parents[1]
PYTHON = sys.executable


def free_ports(n: int):
    # deliberate (stdlib-only) copy of repro.comm.sock.local_addresses'
    # allocation pattern: this driver must run without PYTHONPATH
    socks = [socket.socket() for _ in range(n)]
    for s in socks:
        s.bind(("127.0.0.1", 0))
    ports = [s.getsockname()[1] for s in socks]
    for s in socks:
        s.close()
    return ports


def write_spec(path: pathlib.Path, certs: pathlib.Path, *,
               protocol: str, epochs: int, extra: str = "") -> None:
    p = free_ports(4)
    path.write_text(f"""
[protocol]
name = "{protocol}"
epochs = {epochs}
batch_size = 64
lr = 0.5
seed = 0
use_psi = true
embedding_dim = 16

[run]
phases = ["fit", "evaluate"]

[data]
provider = "repro.launch.cluster:quickstart_data"
seed = 0

[comm]
framing = "grpc"
timeout = 120.0
barrier_timeout = 120.0

[comm.tls]
cert = "{certs}/{{agent}}.crt"
key = "{certs}/{{agent}}.key"
ca = "{certs}/ca.crt"

[agents]
master = "127.0.0.1:{p[0]}"
member0 = "127.0.0.1:{p[1]}"

[hosts.alpha]
control = "127.0.0.1:{p[2]}"
agents = ["master"]

[hosts.beta]
control = "127.0.0.1:{p[3]}"
agents = ["member0"]
{extra}
""")


def launch(spec: pathlib.Path, host: str,
           log_dir: pathlib.Path) -> subprocess.Popen:
    env = dict(os.environ)
    env["PYTHONPATH"] = f"{REPO / 'src'}" + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    return subprocess.Popen(
        [PYTHON, "-m", "repro.launch.cluster", str(spec),
         "--host", host, "--log-dir", str(log_dir)],
        stdout=subprocess.PIPE, stderr=subprocess.STDOUT, text=True,
        env=env, cwd=str(REPO))


def wait_both(procs, timeout: float):
    outs, deadline = {}, time.monotonic() + timeout
    for host, p in procs.items():
        left = max(1.0, deadline - time.monotonic())
        try:
            outs[host], _ = p.communicate(timeout=left)
        except subprocess.TimeoutExpired:
            p.kill()
            outs[host], _ = p.communicate()
            outs[host] += "\n<TIMEOUT: launcher killed by CI driver>"
    return outs


def dump(outs) -> None:
    for host, out in outs.items():
        print(f"\n===== launcher {host} output =====\n{out}")


def check(cond: bool, what: str, outs=None) -> None:
    if cond:
        print(f"PASS: {what}")
        return
    print(f"FAIL: {what}", file=sys.stderr)
    if outs:
        dump(outs)
    sys.exit(1)


def round_convergence(wd: pathlib.Path, certs: pathlib.Path) -> None:
    spec = wd / "quickstart.toml"
    # 6 epochs at lr 0.5: past batch noise on the reduced-scale demo
    # (AUC ~0.76 federated; 3 epochs at the demo lr stays at ~0.55)
    write_spec(spec, certs, protocol="split_nn", epochs=6)
    procs = {h: launch(spec, h, wd / "conv" / h)
             for h in ("alpha", "beta")}
    outs = wait_both(procs, timeout=600)
    rcs = {h: p.returncode for h, p in procs.items()}
    check(rcs == {"alpha": 0, "beta": 0},
          f"both launchers exited 0 (got {rcs})", outs)
    result = next((ln for ln in outs["alpha"].splitlines()
                   if ln.startswith("CLUSTER-RESULT ")), None)
    check(result is not None, "master launcher printed CLUSTER-RESULT",
          outs)
    summary = json.loads(result[len("CLUSTER-RESULT "):])
    fit = summary["agents"]["master"]["fit"]
    check(fit["final_loss"] < fit["first_loss"],
          f"loss decreased ({fit['first_loss']:.4f} -> "
          f"{fit['final_loss']:.4f})", outs)
    auc = summary["agents"]["master"]["evaluate"].get("auc")
    check(auc is not None and auc > 0.7,
          f"federated evaluate AUC sane ({auc})", outs)


def round_chaos(wd: pathlib.Path, certs: pathlib.Path) -> None:
    spec = wd / "chaos.toml"
    # link shaping keeps the run going for minutes, so the kill always
    # lands mid-epoch; the launchers must still exit within seconds
    write_spec(spec, certs, protocol="split_nn", epochs=100,
               extra="[comm.link]\nlatency_ms = 25.0\n")
    procs = {h: launch(spec, h, wd / "chaos" / h)
             for h in ("alpha", "beta")}
    pids = wd / "chaos" / "beta" / "pids.json"
    deadline = time.monotonic() + 300
    while not pids.exists() and time.monotonic() < deadline:
        if any(p.poll() is not None for p in procs.values()):
            break
        time.sleep(0.2)
    check(pids.exists(), "beta launcher reached readiness",
          {h: (p.communicate()[0] if p.poll() is not None else "(running)")
           for h, p in procs.items()})
    time.sleep(10)                      # into the training loop
    t0 = time.monotonic()
    os.kill(json.loads(pids.read_text())["member0"], signal.SIGKILL)
    print("SIGKILLed member0; waiting for launchers ...")
    outs = wait_both(procs, timeout=30)
    dt = time.monotonic() - t0
    rcs = {h: p.returncode for h, p in procs.items()}
    check(all(rc not in (0, None) for rc in rcs.values()),
          f"both launchers exited non-zero after the kill (got {rcs})",
          outs)
    check(dt < 30.0, f"failure propagated in {dt:.1f}s (< 30s)", outs)
    for host in ("alpha", "beta"):
        check("member0" in outs[host],
              f"{host} launcher output names the dead member", outs)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--workdir", default=None)
    args = ap.parse_args()
    wd = pathlib.Path(args.workdir or tempfile.mkdtemp(
        prefix="ci_cluster_"))
    wd.mkdir(parents=True, exist_ok=True)
    certs = wd / "certs"
    rc = subprocess.run(
        [PYTHON, "-m", "repro.launch.certs", "--dir", str(certs),
         "--agents", "master", "member0", "alpha", "beta"],
        env={**os.environ,
             "PYTHONPATH": str(REPO / "src")}).returncode
    check(rc == 0, "test CA + certificates minted")
    round_convergence(wd, certs)
    round_chaos(wd, certs)
    print("ci_cluster: ALL OK")


if __name__ == "__main__":
    main()
