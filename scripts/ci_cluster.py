"""CI cluster job: drive the real launcher CLI end-to-end.

Every round runs as two launcher invocations ("hosts") on localhost
sharing one spec file, TLS on, gRPC framing (i.e. the TLS'd
``grpc_proc`` deployment shape). ``--scenario`` picks one round of
the chaos matrix (the default ``all`` runs the tier-1 pair):

* **convergence** — the quickstart split-NN cluster spec must run to
  completion on both launchers (exit 0) with the training loss
  strictly decreasing and the federated evaluate reporting a sane
  AUC.
* **crash** — relaunch a long link-shaped run, SIGKILL one member
  mid-epoch, and require BOTH launchers to exit non-zero within 30
  seconds naming the dead member (no hang until a transport timeout).
* **rejoin** — same kill, but with ``[restart]`` supervision on the
  member: its launcher must respawn it, the master must accept the
  rejoin, both launchers exit 0, and the final AUC lands within 0.01
  of an uninterrupted reference run.
* **partition** — a ``[chaos]`` blackhole on one member's link must
  fail both launchers attributed, bounded by the transport timeout.
* **slow** — a mid-run latency spike under ``round_deadline_s`` +
  ``pipeline_depth=2`` must NOT fail the run: exit 0 with straggles
  recorded in the summary.
* **serve** — fit + evaluate, then deploy the ``[serve]`` phase
  (persistent federated inference, docs/serving.md); a probe
  subprocess (scripts/ci_serve_probe.py) drives 200 concurrent
  queries covering every row, and the served AUC must match the
  offline evaluate within 0.01 with p99 latency bounded.
* **multi_crash** — a ``[chaos]`` role *list* crashes BOTH members in
  the same round (correlated failure); both launchers must exit
  non-zero fast with the fault attributed.
* **master_member_crash** — master and member crash together; with no
  survivor to coordinate, each launcher must still notice its own
  agent's death and exit non-zero attributed.
* **crash_loop** — ``[chaos] repeat=true`` under ``[restart]``
  supervision: the respawned member resumes at/past the chaos step
  and re-crashes until ``max_restarts`` is exhausted; the run must
  END in an attributed terminal failure, not a supervision livelock.

Exits non-zero on the first violated assertion, printing both
launchers' output. Stdlib only (the serve probe needs repro and runs
as a subprocess with PYTHONPATH set, like the launchers).

  PYTHONPATH=src python scripts/ci_cluster.py [--workdir DIR]
      [--scenario {all,convergence,crash,partition,slow,rejoin,serve,
                   multi_crash,master_member_crash,crash_loop}]
"""
from __future__ import annotations

import argparse
import json
import os
import pathlib
import signal
import socket
import subprocess
import sys
import tempfile
import time

REPO = pathlib.Path(__file__).resolve().parents[1]
PYTHON = sys.executable


def free_ports(n: int):
    # deliberate (stdlib-only) copy of repro.comm.sock.local_addresses'
    # allocation pattern: this driver must run without PYTHONPATH
    socks = [socket.socket() for _ in range(n)]
    for s in socks:
        s.bind(("127.0.0.1", 0))
    ports = [s.getsockname()[1] for s in socks]
    for s in socks:
        s.close()
    return ports


def write_spec(path: pathlib.Path, certs: pathlib.Path, *,
               protocol: str, epochs: int, extra: str = "",
               timeout: float = 120.0,
               protocol_extra: str = "",
               phases: str = '["fit", "evaluate"]',
               provider: str = "repro.launch.cluster:quickstart_data",
               members: int = 1) -> None:
    # alpha owns the master, beta owns every member (>1 member only
    # for providers that ship more than one silo, e.g. the linreg demo)
    p = free_ports(3 + members)
    names = [f"member{i}" for i in range(members)]
    agent_lines = "\n".join(
        f'{m} = "127.0.0.1:{p[1 + i]}"' for i, m in enumerate(names))
    beta_agents = "[" + ", ".join(f'"{m}"' for m in names) + "]"
    path.write_text(f"""
[protocol]
name = "{protocol}"
epochs = {epochs}
batch_size = 64
lr = 0.5
seed = 0
use_psi = true
embedding_dim = 16
{protocol_extra}
[run]
phases = {phases}

[data]
provider = "{provider}"
seed = 0

[comm]
framing = "grpc"
timeout = {timeout}
barrier_timeout = 120.0

[comm.tls]
cert = "{certs}/{{agent}}.crt"
key = "{certs}/{{agent}}.key"
ca = "{certs}/ca.crt"

[agents]
master = "127.0.0.1:{p[0]}"
{agent_lines}

[hosts.alpha]
control = "127.0.0.1:{p[1 + members]}"
agents = ["master"]

[hosts.beta]
control = "127.0.0.1:{p[2 + members]}"
agents = {beta_agents}
{extra}
""")


def launch(spec: pathlib.Path, host: str,
           log_dir: pathlib.Path) -> subprocess.Popen:
    env = dict(os.environ)
    env["PYTHONPATH"] = f"{REPO / 'src'}" + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    return subprocess.Popen(
        [PYTHON, "-m", "repro.launch.cluster", str(spec),
         "--host", host, "--log-dir", str(log_dir)],
        stdout=subprocess.PIPE, stderr=subprocess.STDOUT, text=True,
        env=env, cwd=str(REPO))


def wait_both(procs, timeout: float):
    outs, deadline = {}, time.monotonic() + timeout
    for host, p in procs.items():
        left = max(1.0, deadline - time.monotonic())
        try:
            outs[host], _ = p.communicate(timeout=left)
        except subprocess.TimeoutExpired:
            p.kill()
            outs[host], _ = p.communicate()
            outs[host] += "\n<TIMEOUT: launcher killed by CI driver>"
    return outs


def dump(outs) -> None:
    for host, out in outs.items():
        print(f"\n===== launcher {host} output =====\n{out}")


def check(cond: bool, what: str, outs=None) -> None:
    if cond:
        print(f"PASS: {what}")
        return
    print(f"FAIL: {what}", file=sys.stderr)
    if outs:
        dump(outs)
    sys.exit(1)


def run_pair(spec: pathlib.Path, log_root: pathlib.Path, *,
             timeout: float):
    procs = {h: launch(spec, h, log_root / h)
             for h in ("alpha", "beta")}
    outs = wait_both(procs, timeout=timeout)
    rcs = {h: p.returncode for h, p in procs.items()}
    return procs, outs, rcs


def master_summary(outs) -> dict:
    result = next((ln for ln in outs["alpha"].splitlines()
                   if ln.startswith("CLUSTER-RESULT ")), None)
    check(result is not None, "master launcher printed CLUSTER-RESULT",
          outs)
    return json.loads(result[len("CLUSTER-RESULT "):])


def wait_for_file(path: pathlib.Path, procs, timeout: float,
                  what: str) -> None:
    deadline = time.monotonic() + timeout
    while not path.exists() and time.monotonic() < deadline:
        if any(p.poll() is not None for p in procs.values()):
            break
        time.sleep(0.2)
    check(path.exists(), what,
          {h: (p.communicate()[0] if p.poll() is not None
               else "(running)") for h, p in procs.items()})


def round_convergence(wd: pathlib.Path, certs: pathlib.Path) -> float:
    spec = wd / "quickstart.toml"
    # 6 epochs at lr 0.5: past batch noise on the reduced-scale demo
    # (AUC ~0.76 federated; 3 epochs at the demo lr stays at ~0.55)
    write_spec(spec, certs, protocol="split_nn", epochs=6)
    _, outs, rcs = run_pair(spec, wd / "conv", timeout=600)
    check(rcs == {"alpha": 0, "beta": 0},
          f"both launchers exited 0 (got {rcs})", outs)
    summary = master_summary(outs)
    fit = summary["agents"]["master"]["fit"]
    check(fit["final_loss"] < fit["first_loss"],
          f"loss decreased ({fit['first_loss']:.4f} -> "
          f"{fit['final_loss']:.4f})", outs)
    auc = summary["agents"]["master"]["evaluate"].get("auc")
    check(auc is not None and auc > 0.7,
          f"federated evaluate AUC sane ({auc})", outs)
    return float(auc)


def round_crash(wd: pathlib.Path, certs: pathlib.Path) -> None:
    spec = wd / "chaos.toml"
    # link shaping keeps the run going for minutes, so the kill always
    # lands mid-epoch; the launchers must still exit within seconds
    write_spec(spec, certs, protocol="split_nn", epochs=100,
               extra="[comm.link]\nlatency_ms = 25.0\n")
    procs = {h: launch(spec, h, wd / "chaos" / h)
             for h in ("alpha", "beta")}
    pids = wd / "chaos" / "beta" / "pids.json"
    wait_for_file(pids, procs, 300, "beta launcher reached readiness")
    time.sleep(10)                      # into the training loop
    t0 = time.monotonic()
    os.kill(json.loads(pids.read_text())["member0"], signal.SIGKILL)
    print("SIGKILLed member0; waiting for launchers ...")
    outs = wait_both(procs, timeout=30)
    dt = time.monotonic() - t0
    rcs = {h: p.returncode for h, p in procs.items()}
    check(all(rc not in (0, None) for rc in rcs.values()),
          f"both launchers exited non-zero after the kill (got {rcs})",
          outs)
    check(dt < 30.0, f"failure propagated in {dt:.1f}s (< 30s)", outs)
    for host in ("alpha", "beta"):
        check("member0" in outs[host],
              f"{host} launcher output names the dead member", outs)


def round_rejoin(wd: pathlib.Path, certs: pathlib.Path) -> None:
    # uninterrupted reference: the acceptance bar is |AUC delta| < 0.01
    # against the exact same protocol config (convergence round spec)
    ref_auc = round_convergence(wd, certs)

    spec = wd / "rejoin.toml"
    # link latency stretches fit so the kill lands well inside it; the
    # restart block makes member0's death supervised instead of fatal
    write_spec(spec, certs, protocol="split_nn", epochs=6,
               extra=("[comm.link]\nlatency_ms = 40.0\n\n"
                      "[restart.member0]\npolicy = \"on_failure\"\n"
                      "backoff_s = 0.5\nbackoff_max_s = 2.0\n"
                      "wait_s = 90.0\n"))
    procs = {h: launch(spec, h, wd / "rejoin" / h)
             for h in ("alpha", "beta")}
    pids = wd / "rejoin" / "beta" / "pids.json"
    wait_for_file(pids, procs, 300, "beta launcher reached readiness")
    # the member's Checkpointer (save_on_start) writes its first cut
    # when fit begins — killing after that is guaranteed mid-fit
    ckpt = wd / "rejoin" / "beta" / "ckpt"
    wait_for_file(ckpt / "member0.pkl", procs, 300,
                  "member0 wrote its first checkpoint (fit started)")
    time.sleep(3)                       # a few steps into the epoch
    os.kill(json.loads(pids.read_text())["member0"], signal.SIGKILL)
    print("SIGKILLed member0; waiting for supervised recovery ...")
    outs = wait_both(procs, timeout=600)
    rcs = {h: p.returncode for h, p in procs.items()}
    check(rcs == {"alpha": 0, "beta": 0},
          f"both launchers exited 0 after the recovery (got {rcs})",
          outs)
    summary = master_summary(outs)
    recs = summary["agents"]["master"].get("recoveries") or []
    check([r["role"] for r in recs] == ["member0"],
          f"master recorded exactly one member0 recovery (got {recs})",
          outs)
    check(recs[0]["wait_s"] < 15.0,
          f"recovery took {recs[0]['wait_s']:.1f}s (< 15s)", outs)
    auc = summary["agents"]["master"]["evaluate"].get("auc")
    check(auc is not None and abs(auc - ref_auc) < 0.01,
          f"AUC within 0.01 of uninterrupted run "
          f"({auc} vs {ref_auc})", outs)


def round_partition(wd: pathlib.Path, certs: pathlib.Path) -> None:
    spec = wd / "partition.toml"
    # blackhole member0's link at step 5: sends "succeed" locally and
    # vanish, so the master can only fail via its transport timeout —
    # lowered here so the round is bounded
    write_spec(spec, certs, protocol="split_nn", epochs=100,
               timeout=20.0,
               extra=("[chaos]\nrole = \"member0\"\nstep = 5\n"
                      "scenario = \"partition\"\n"))
    t0 = time.monotonic()
    _, outs, rcs = run_pair(spec, wd / "partition", timeout=240)
    dt = time.monotonic() - t0
    check(all(rc not in (0, None) for rc in rcs.values()),
          f"both launchers exited non-zero after the blackhole "
          f"(got {rcs})", outs)
    check(dt < 180.0, f"partition detected in {dt:.1f}s (< 180s)",
          outs)
    check("member0" in outs["alpha"],
          "alpha launcher output attributes the partition", outs)


def round_slow(wd: pathlib.Path, certs: pathlib.Path) -> None:
    spec = wd / "slow.toml"
    # member0's link latency jumps to 400ms at step 5; with a 150ms
    # round deadline at depth 2 the master must substitute stale
    # contributions instead of stalling — exit 0, straggles recorded
    write_spec(spec, certs, protocol="split_nn", epochs=6,
               protocol_extra=("pipeline_depth = 2\n"
                               "round_deadline_s = 0.15\n"),
               extra=("[chaos]\nrole = \"member0\"\nstep = 5\n"
                      "scenario = \"slow\"\nlatency_ms = 400.0\n"))
    _, outs, rcs = run_pair(spec, wd / "slow", timeout=600)
    check(rcs == {"alpha": 0, "beta": 0},
          f"both launchers exited 0 under the latency spike "
          f"(got {rcs})", outs)
    summary = master_summary(outs)
    fit = summary["agents"]["master"]["fit"]
    check(fit["final_loss"] < fit["first_loss"],
          f"loss decreased ({fit['first_loss']:.4f} -> "
          f"{fit['final_loss']:.4f})", outs)
    straggles = (summary["agents"]["master"].get("comm") or {}) \
        .get("straggles") or {}
    check(sum(straggles.values()) > 0,
          f"master recorded straggles (got {straggles})", outs)


def round_multi_crash(wd: pathlib.Path, certs: pathlib.Path) -> None:
    spec = wd / "multi_crash.toml"
    # correlated failure: BOTH members crash in the same round (a
    # [chaos] role *list*). The member host sees two near-simultaneous
    # deaths; its launcher must fail once, attributed, and the master
    # host must follow via the control channel — no hang
    write_spec(spec, certs, protocol="linreg", epochs=100,
               members=2,
               provider="repro.launch.cluster:linreg_demo_data",
               extra=('[chaos]\nrole = ["member0", "member1"]\n'
                      'step = 5\nscenario = "crash"\n'))
    t0 = time.monotonic()
    _, outs, rcs = run_pair(spec, wd / "multi_crash", timeout=180)
    dt = time.monotonic() - t0
    check(all(rc not in (0, None) for rc in rcs.values()),
          f"both launchers exited non-zero after the correlated "
          f"member crash (got {rcs})", outs)
    check(dt < 120.0,
          f"correlated failure propagated in {dt:.1f}s (< 120s)", outs)
    check("chaos: injected crash" in outs["beta"],
          "beta launcher output attributes the injected crash", outs)
    check(any(f"agent member{i} FAILED" in outs["beta"]
              for i in (0, 1)),
          "beta launcher output names a crashed member", outs)


def round_master_member_crash(wd: pathlib.Path,
                              certs: pathlib.Path) -> None:
    spec = wd / "mm_crash.toml"
    # master AND member crash in the same round: neither host has a
    # survivor to coordinate shutdown, so each launcher must notice
    # its OWN agent's death locally and still exit non-zero fast
    write_spec(spec, certs, protocol="split_nn", epochs=100,
               extra=('[chaos]\nrole = ["master", "member0"]\n'
                      'step = 5\nscenario = "crash"\n'))
    t0 = time.monotonic()
    _, outs, rcs = run_pair(spec, wd / "mm_crash", timeout=300)
    dt = time.monotonic() - t0
    check(all(rc not in (0, None) for rc in rcs.values()),
          f"both launchers exited non-zero after the master+member "
          f"crash (got {rcs})", outs)
    check(dt < 240.0,
          f"correlated failure propagated in {dt:.1f}s (< 240s)", outs)
    # both victims die in the same round, so which failure a given
    # launcher reports first (its own agent vs the peer's ctl/fail) is
    # a race — require attribution, not a specific victim
    for host in ("alpha", "beta"):
        check("FAILED" in outs[host]
              and "chaos: injected crash" in outs[host],
              f"{host} launcher attributes the injected crash", outs)


def round_crash_loop(wd: pathlib.Path, certs: pathlib.Path) -> None:
    spec = wd / "crash_loop.toml"
    # a repeating fault under supervision: [chaos] repeat=true re-arms
    # the crash on every respawn, and the checkpoint-restored member
    # resumes at/past the chaos step — so it dies again immediately,
    # burning the whole [restart] budget. The scenario must END (no
    # supervision livelock): budget exhaustion logged and attributed,
    # both launchers non-zero, bounded wall clock
    write_spec(spec, certs, protocol="split_nn", epochs=100,
               extra=('[chaos]\nrole = "member0"\nstep = 5\n'
                      'scenario = "crash"\nrepeat = true\n\n'
                      '[restart.member0]\npolicy = "on_failure"\n'
                      'max_restarts = 2\nbackoff_s = 0.2\n'
                      'backoff_max_s = 0.5\nwait_s = 90.0\n'))
    t0 = time.monotonic()
    _, outs, rcs = run_pair(spec, wd / "crash_loop", timeout=420)
    dt = time.monotonic() - t0
    check(all(rc not in (0, None) for rc in rcs.values()),
          f"both launchers exited non-zero after the crash loop "
          f"(got {rcs})", outs)
    check(dt < 360.0, f"crash loop terminated in {dt:.1f}s (< 360s)",
          outs)
    check("restart 2/2" in outs["beta"],
          "member0 was respawned up to its budget", outs)
    check("exhausted its restart budget (2)" in outs["beta"],
          "beta launcher attributes the exhausted restart budget",
          outs)
    check("agent member0 FAILED" in outs["beta"],
          "beta launcher names the terminally failed member", outs)


def round_serve(wd: pathlib.Path, certs: pathlib.Path) -> None:
    spec = wd / "serve.toml"
    sdir = wd / "serve"
    sdir.mkdir(parents=True, exist_ok=True)
    port = free_ports(1)[0]
    stop = sdir / "stop"
    # fit + offline evaluate, then serve behind the TCP frontend until
    # the probe is done (stop_file; duration_s only as a safety bound)
    write_spec(spec, certs, protocol="split_nn", epochs=6,
               phases='["fit", "evaluate", "serve"]',
               extra=(f'[serve]\nhost = "127.0.0.1"\nport = {port}\n'
                      f'max_batch = 64\nmax_wait_ms = 2.0\n'
                      f'duration_s = 300.0\n'
                      f'stop_file = "{stop}"\n'))
    procs = {h: launch(spec, h, sdir / h) for h in ("alpha", "beta")}
    out_json = sdir / "probe.json"
    env = dict(os.environ)
    env["PYTHONPATH"] = f"{REPO / 'src'}" + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    probe = subprocess.Popen(
        [PYTHON, str(REPO / "scripts" / "ci_serve_probe.py"),
         "--port", str(port), "--requests", "200",
         "--out", str(out_json)],
        stdout=subprocess.PIPE, stderr=subprocess.STDOUT, text=True,
        env=env, cwd=str(REPO))
    try:
        probe_out, _ = probe.communicate(timeout=600)
    except subprocess.TimeoutExpired:
        probe.kill()
        probe_out, _ = probe.communicate()
    finally:
        stop.write_text("done")         # end the serve phase either way
    outs = wait_both(procs, timeout=120)
    print(f"\n===== probe output =====\n{probe_out}")
    check(probe.returncode == 0,
          f"probe completed its query load (rc {probe.returncode})",
          outs)
    rcs = {h: p.returncode for h, p in procs.items()}
    check(rcs == {"alpha": 0, "beta": 0},
          f"both launchers exited 0 after serving (got {rcs})", outs)
    res = json.loads(out_json.read_text())
    check(res["requests"] >= 200,
          f"probe drove {res['requests']} concurrent queries (>= 200)",
          outs)
    check(res["p99_ms"] < 2000.0,
          f"served p99 bounded ({res['p99_ms']:.1f}ms < 2000ms)", outs)
    summary = master_summary(outs)
    auc_off = summary["agents"]["master"]["evaluate"]["auc"]
    check(abs(res["auc"] - auc_off) < 0.01,
          f"served AUC matches offline evaluate "
          f"({res['auc']:.4f} vs {auc_off:.4f})", outs)
    srv = summary["agents"]["master"].get("serve") or {}
    check(srv.get("requests", 0) >= res["requests"],
          f"master serve stats recorded the load (got {srv})", outs)


SCENARIOS = {
    "convergence": round_convergence,
    "crash": round_crash,
    "rejoin": round_rejoin,
    "partition": round_partition,
    "slow": round_slow,
    "serve": round_serve,
    "multi_crash": round_multi_crash,
    "master_member_crash": round_master_member_crash,
    "crash_loop": round_crash_loop,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--workdir", default=None)
    ap.add_argument("--scenario", default="all",
                    choices=["all"] + sorted(SCENARIOS))
    args = ap.parse_args()
    wd = pathlib.Path(args.workdir or tempfile.mkdtemp(
        prefix="ci_cluster_"))
    wd.mkdir(parents=True, exist_ok=True)
    certs = wd / "certs"
    rc = subprocess.run(
        [PYTHON, "-m", "repro.launch.certs", "--dir", str(certs),
         "--agents", "master", "member0", "member1", "alpha", "beta"],
        env={**os.environ,
             "PYTHONPATH": str(REPO / "src")}).returncode
    check(rc == 0, "test CA + certificates minted")
    if args.scenario == "all":
        # the tier-1 set every CI run gets; the rest of the matrix is
        # dispatched per-scenario by the chaos-matrix workflow job
        round_convergence(wd, certs)
        round_crash(wd, certs)
        round_serve(wd, certs)
    else:
        SCENARIOS[args.scenario](wd, certs)
    print("ci_cluster: ALL OK")


if __name__ == "__main__":
    main()
