"""CI docs job: keep the user-facing docs honest.

Three checks, any failure exits non-zero:

1. **Quickstart executes** — every fenced python block preceded by a
   ``<!-- docs-check: execute -->`` marker (README.md and docs/*.md)
   runs in-process and must not raise.
2. **Links resolve** — every intra-repo markdown link in tracked
   markdown files must point at an existing file (anchors are
   stripped; http(s) links are skipped).
3. **API surface intact** — every symbol heading in the generated
   docs/api.md (`### \`module.Symbol\``) must still import; a removed
   public symbol fails CI until docs/gen_api.py is rerun (making the
   removal a conscious diff).

  PYTHONPATH=src python docs/check.py
"""
from __future__ import annotations

import importlib
import pathlib
import re
import sys
import traceback

ROOT = pathlib.Path(__file__).resolve().parents[1]
MD_FILES = sorted(
    list(ROOT.glob("*.md")) + list((ROOT / "docs").glob("*.md")))

_EXEC_MARK = "<!-- docs-check: execute -->"
_FENCE = re.compile(r"```python\n(.*?)```", re.S)
_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
_API_SYM = re.compile(r"^### `([\w.]+)\.(\w+)`", re.M)

failures: list = []


def check_snippets() -> int:
    ran = 0
    for md in MD_FILES:
        text = md.read_text()
        for m in _FENCE.finditer(text):
            head = text[:m.start()].rstrip()
            if not head.endswith(_EXEC_MARK):
                continue
            ran += 1
            print(f"[snippet] executing block from {md.name} ...")
            try:
                exec(compile(m.group(1), f"{md.name}:snippet", "exec"),
                     {"__name__": "__docs_check__"})
            except BaseException:
                failures.append(f"snippet in {md.name} raised:\n"
                                f"{traceback.format_exc()}")
    if ran == 0:
        failures.append("no executable snippets found — the README "
                        f"quickstart must carry {_EXEC_MARK!r}")
    return ran


def check_links() -> int:
    n = 0
    for md in MD_FILES:
        for target in _LINK.findall(md.read_text()):
            if target.startswith(("http://", "https://", "mailto:")):
                continue
            path = target.split("#", 1)[0]
            if not path:                     # pure in-page anchor
                continue
            n += 1
            resolved = (md.parent / path).resolve()
            if not resolved.exists():
                failures.append(f"{md.relative_to(ROOT)}: broken link "
                                f"-> {target}")
    return n


def check_api_surface() -> int:
    api = ROOT / "docs" / "api.md"
    if not api.exists():
        failures.append("docs/api.md missing — run docs/gen_api.py")
        return 0
    syms = _API_SYM.findall(api.read_text())
    if not syms:
        failures.append("docs/api.md lists no symbols — regenerate it")
    for modname, name in syms:
        try:
            mod = importlib.import_module(modname)
        except ImportError as e:
            failures.append(f"api.md module {modname} gone: {e}")
            continue
        if not hasattr(mod, name):
            failures.append(f"public symbol {modname}.{name} listed in "
                            f"docs/api.md no longer exists")
    return len(syms)


def main() -> None:
    n_snip = check_snippets()
    n_links = check_links()
    n_syms = check_api_surface()
    print(f"docs-check: {n_snip} snippet(s) executed, {n_links} links "
          f"checked, {n_syms} API symbols verified")
    if failures:
        print("\n".join(f"FAIL: {f}" for f in failures), file=sys.stderr)
        sys.exit(1)
    print("docs-check: OK")


if __name__ == "__main__":
    main()
