"""End-to-end driver: train a ~100M-parameter dense LM for a few hundred
steps on CPU, with checkpointing, metric logging, and a resume check.

  PYTHONPATH=src python examples/train_lm.py [--steps 300]

The config is a scaled-down qwen3-family model (~100M params with the
reduced vocab) — the same code path the dry-run proves on the 256-chip
mesh.
"""
import argparse
import dataclasses
import pathlib
import tempfile

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.data.synthetic import make_lm_batches
from repro.models import params as PRM, transformer as T
from repro.train import checkpoint as CKPT
from repro.train.trainer import TrainJob, train

OUT = pathlib.Path(__file__).resolve().parents[1] \
    / "benchmarks" / "results" / "train_lm"


def small_qwen():
    base = get_config("qwen3-14b")
    return dataclasses.replace(
        base, n_layers=8, d_model=512, n_heads=8, n_kv_heads=4,
        head_dim=64, d_ff=1536, vocab=8192, remat_policy="none")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    args = ap.parse_args()

    cfg = small_qwen()
    spec = T.model_spec(cfg)
    n_params = PRM.param_bytes(spec, 4) // 4
    print(f"model: {n_params/1e6:.1f}M params "
          f"({cfg.n_layers}L d={cfg.d_model} vocab={cfg.vocab})")

    ckpt_dir = str(OUT / "ckpt")
    job = TrainJob(cfg=cfg, lr=1e-3, steps=args.steps,
                   log_every=max(1, args.steps // 25),
                   ckpt_every=args.steps // 2, ckpt_dir=ckpt_dir,
                   metrics_dir=str(OUT))
    res = train(job, make_lm_batches(cfg.vocab, args.batch, args.seq,
                                     args.steps + 1))
    first = res["history"][0]["loss"]
    last = res["history"][-1]["loss"]
    print(f"loss: {first:.3f} -> {last:.3f} "
          f"({res['history'][-1]['tokens_per_s']:.0f} tok/s)")
    assert last < first, "training must reduce loss"

    # resume check: restore latest checkpoint and verify identical loss
    step = CKPT.latest_step(ckpt_dir)
    params_like = PRM.abstract_tree(spec, jnp.float32)
    restored, _ = CKPT.restore(ckpt_dir, step, res["params"])
    batch = next(make_lm_batches(cfg.vocab, args.batch, args.seq, 1,
                                 seed=123))
    jb = {k: jnp.asarray(v) for k, v in batch.items()}
    l1, _ = T.loss_fn(cfg, res["params"], jb, jnp.float32)
    l2, _ = T.loss_fn(cfg, restored, jb, jnp.float32)
    print(f"checkpoint roundtrip: {float(l1):.6f} == {float(l2):.6f}")
    assert abs(float(l1) - float(l2)) < 1e-5


if __name__ == "__main__":
    main()
