"""VFL x LLM: the paper's technique applied to an assigned architecture.

Two feature silos jointly train a (reduced) granite-MoE classifier head:
members own *vertically split embedding front-ends* (each silo sees a
disjoint slice of the user-feature vector), the master owns the
transformer backbone + labels. The exchange is the masked-psum mesh VFL
step over the "pod" axis — i.e. a data silo == a pod, exactly the
multi-pod story of DESIGN.md §5.

  PYTHONPATH=src python examples/vfl_llm.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=4")

import jax                                                  # noqa: E402
import jax.numpy as jnp                                     # noqa: E402
import numpy as np                                          # noqa: E402

from repro.configs import get_config                        # noqa: E402
from repro.core import secure_agg                           # noqa: E402
from repro.models import params as PRM, transformer as T    # noqa: E402


def main():
    n_parties, B, d_feat = 2, 8, 32
    cfg = get_config("granite-moe-3b-a800m").reduced()
    from repro.launch.mesh import make_mesh
    mesh = make_mesh((n_parties, 2), ("pod", "data"))

    key = jax.random.key(0)
    spec = T.model_spec(cfg)
    backbone = PRM.init_tree(spec, key, jnp.float32)       # master-owned
    # member-owned feature front-ends: slice -> pseudo-token embeddings
    seq = 16
    fronts = jax.random.normal(jax.random.fold_in(key, 1),
                               (n_parties, d_feat, seq * cfg.d_model),
                               jnp.float32) * 0.02

    x = jax.random.normal(jax.random.fold_in(key, 2),
                          (n_parties, B, d_feat), jnp.float32)
    labels = jax.random.randint(jax.random.fold_in(key, 3), (B, seq),
                                0, cfg.vocab)

    def loss_fn(fronts, backbone, mask_key):
        def party_embed(front_p, x_p):
            emb = (x_p[0] @ front_p[0]).reshape(B, seq, cfg.d_model)
            idx = jax.lax.axis_index("pod")
            masks = jnp.stack([
                secure_agg.pairwise_mask(mask_key, i, n_parties, emb.shape)
                for i in range(n_parties)])
            return jax.lax.psum(emb + masks[idx], "pod")

        agg = jax.shard_map(
            party_embed, mesh=mesh,
            in_specs=(jax.sharding.PartitionSpec("pod"),
                      jax.sharding.PartitionSpec("pod")),
            out_specs=jax.sharding.PartitionSpec())(fronts, x)
        # master backbone consumes the aggregated silo embeddings as
        # soft tokens: replace the embedding table path
        h, aux = T._stack_forward(cfg, backbone, agg)
        h = T._norm(cfg, backbone["final_norm"], h)
        logits = jnp.einsum("bsd,dv->bsv", h, backbone["lm_head"]["w"])
        from repro.models.layers import softmax_xent
        loss, _ = softmax_xent(logits, labels)
        return loss + 0.01 * aux["load_balance"]

    step = jax.jit(jax.value_and_grad(loss_fn, argnums=(0, 1)))
    lr = 0.05
    with mesh:
        for i in range(8):
            (loss), (g_f, g_b) = step(fronts, backbone,
                                      jax.random.fold_in(key, 100 + i))
            fronts = jax.tree.map(lambda p, g: p - lr * g, fronts, g_f)
            backbone = jax.tree.map(lambda p, g: p - lr * g, backbone, g_b)
            print(f"step {i}: loss {float(loss):.4f}")
    print("VFL-LLM (granite-moe backbone, 2 silo pods) trained OK")


if __name__ == "__main__":
    main()
