"""Serve the encoder-decoder (whisper) family: batched transcription-
style decoding against stub frame embeddings — exercises the
cross-attention + enc-dec cache path through the public API.

  PYTHONPATH=src python examples/asr_serve.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import params as PRM, transformer as T
from repro.serve.engine import ServeEngine


def main():
    cfg = get_config("whisper-large-v3").reduced()
    key = jax.random.key(0)
    params = PRM.init_tree(T.model_spec(cfg), key, jnp.float32)

    batch = 4
    # frontend stub: precomputed mel/conv frame embeddings per assignment
    frames = jax.random.normal(
        jax.random.fold_in(key, 1),
        (batch, cfg.encoder.n_frames, cfg.d_model), jnp.float32) * 0.02
    t0 = time.perf_counter()
    memory = T.encode(cfg, params, frames)
    enc_dt = time.perf_counter() - t0

    engine = ServeEngine(cfg, params, max_seq=48)
    bos = np.full((batch, 1), 1, np.int32)
    t0 = time.perf_counter()
    out = engine.generate(bos, 32, temperature=0.7, memory=memory)
    dec_dt = time.perf_counter() - t0
    print(f"encoded {batch}x{cfg.encoder.n_frames} frames in {enc_dt:.2f}s; "
          f"decoded {out.shape} in {dec_dt:.2f}s "
          f"({batch * 32 / dec_dt:.1f} tok/s)")
    print("sample:", out[0, 1:12])


if __name__ == "__main__":
    main()
