"""Quickstart: the paper's core loop in ~50 lines, on the lifecycle API.

Builds the SBOL-like two-silo recommendation dataset, then runs a
:class:`~repro.core.party.VFLJob` — fit, federated evaluate (members
answer feature-slice queries; nobody's raw data moves), shutdown — in
local (thread) mode, then re-runs the identical protocol over TCP
sockets and over the gRPC-framed transport (``mode="grpc"``,
DESIGN.md §8): the seamless mode switch that is Stalactite's headline
feature, now across the full matrix in README.md.

The socket and grpc runs are repeated with ``pipeline_depth=2``
(DESIGN.md §7): the master announces rounds one step ahead, members
run their bottom forward with gradients at most one step stale, and
compute overlaps the in-flight exchange — same protocol code, one
knob. Other knobs this demo inherits by default: ``he_packed=True``
(SIMD Paillier for the arbitered protocol, DESIGN.md §3) and
``CommCfg.encode_offload=True`` (isend serialization off the critical
path). Add ``comm_cfg=CommCfg(link=LinkSpec(latency_ms=20))`` to any
job to emulate a WAN deployment (docs/transports.md), or
``CommCfg(tls=TLSSpec(...))`` to encrypt the TCP modes; to span real
machines, the same protocol/config runs under the cluster launcher —
see docs/deploy.md and examples/cluster/quickstart_cluster.toml.

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.configs.vfl_recsys import VFLRecsysConfig
from repro.core.party import VFLJob
from repro.core.protocols.base import MasterData, MemberData, VFLConfig
from repro.data.synthetic import make_recsys_silos


def main():
    dcfg = VFLRecsysConfig().reduced()
    data = make_recsys_silos(dcfg, seed=0)
    master = MasterData(data.ids, data.labels.astype(np.float64),
                        data.features)
    members = [MemberData(ids, x) for ids, x in
               zip(data.member_ids, data.member_features)]

    cfg = VFLConfig(protocol="split_nn", epochs=3, batch_size=64,
                    lr=0.05, seed=0, use_psi=True, embedding_dim=16)

    for mode, depth in (("thread", 1), ("socket", 1), ("socket", 2),
                        ("grpc", 2)):
        with VFLJob(cfg, master, members, mode=mode,
                    pipeline_depth=depth) as job:
            fit = job.fit()
            metrics = job.evaluate()          # predict + rank metrics
            h = fit["history"]
            stats = job.shutdown()["master"]["comm"]
        print(f"[{mode:6s} d={depth}] matched {fit['n_common']} users | "
              f"loss {h[0]['loss']:.4f} -> {h[-1]['loss']:.4f} | "
              f"AUC {metrics['auc']:.3f} | "
              f"{stats['sent_messages']} msgs, {stats['sent_bytes']:,} B "
              f"| fit {h[-1]['wall_s']:.2f}s")


if __name__ == "__main__":
    main()
