"""The paper's §4 demo, end to end: SBOL-like master silo + MegaMarket-
like member silo, arbiterless (linreg / split-NN) and arbitered
(Paillier-HE logreg) experiments, with the paper's logging (payload
bytes, exchange time, ML metrics) written to benchmarks/results/demo/.

Each experiment is a :class:`~repro.core.party.VFLJob`: after fit, the
SAME live agents serve a federated predict phase — members answer
feature-slice queries, the master assembles scores — so the post-
training AUC comes from the protocol itself, not from an evaluator that
secretly holds every silo.

  PYTHONPATH=src python examples/vfl_recsys_demo.py [--full] [--mode M]

--full uses the published SBOL scale (190k users); default is a reduced
scale so the demo finishes in seconds on CPU. --mode picks any
execution mode from the README matrix (thread / process / socket /
socket_proc / grpc / grpc_proc) — identical protocol code either way.
Current config knobs exercised here: ``he_packed=True`` by default
(packed SIMD Paillier, DESIGN.md §3 — the arbiter decrypts ~K× fewer
ciphertexts), and ``pipeline_depth`` / ``comm_cfg`` pass straight
through :class:`~repro.core.party.VFLJob` for bounded-staleness
pipelining (DESIGN.md §7) and WAN link emulation (DESIGN.md §8).
"""
import argparse
import json
import pathlib

import numpy as np

from repro.configs.vfl_recsys import VFLRecsysConfig
from repro.core.party import VFLJob
from repro.core.protocols.base import MasterData, MemberData, VFLConfig
from repro.data.synthetic import make_recsys_silos

OUT = pathlib.Path(__file__).resolve().parents[1] \
    / "benchmarks" / "results" / "demo"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--mode", default="thread",
                    choices=("thread", "process", "socket",
                             "socket_proc", "grpc", "grpc_proc"))
    args = ap.parse_args()

    dcfg = VFLRecsysConfig() if args.full else VFLRecsysConfig().reduced()
    data = make_recsys_silos(dcfg, seed=0)
    master = MasterData(data.ids, data.labels.astype(np.float64),
                        data.features)
    members = [MemberData(ids, x) for ids, x in
               zip(data.member_ids, data.member_features)]
    OUT.mkdir(parents=True, exist_ok=True)
    summary = {}

    # 1. arbiterless VFL linear regression on implicit labels
    cfg = VFLConfig(protocol="linreg", epochs=4, batch_size=128, lr=0.05,
                    seed=0, use_psi=False)
    with VFLJob(cfg, master, members, mode=args.mode) as job:
        fit = job.fit()
        metrics = job.evaluate()
        res = job.shutdown()
    summary["linreg"] = {
        "loss_first": fit["history"][0]["loss"],
        "loss_last": fit["history"][-1]["loss"],
        **metrics,
        "comm": res["master"]["comm"],
    }

    # 2. split-NN recommender (the paper's demo model family) — rank
    # quality via the federated predict phase on the live agents
    cfg = VFLConfig(protocol="split_nn", epochs=30, batch_size=128, lr=0.3,
                    seed=0, use_psi=True, embedding_dim=dcfg.embedding_dim,
                    hidden=tuple(dcfg.bottom_dims[-1:]))
    with VFLJob(cfg, master, members, mode=args.mode) as job:
        fit = job.fit()
        report = job.evaluate()           # AUC / precision@5 / ndcg@5
        res = job.shutdown()
    summary["split_nn"] = {
        "loss_first": fit["history"][0]["loss"],
        "loss_last": fit["history"][-1]["loss"],
        "n_common": fit["n_common"],
        **report,
        "phase_s": res["master"]["phase_s"],
        "comm": res["master"]["comm"],
    }

    # 3. arbitered HE logreg on product 0 (binary); predict needs no HE,
    # so post-training AUC is one cheap plaintext round
    yb = master.y[:, :1]
    cfg = VFLConfig(protocol="logreg_he", epochs=1, batch_size=32, lr=0.5,
                    seed=0, use_psi=False, he_bits=256)
    with VFLJob(cfg, MasterData(master.ids, yb, master.x), members,
                mode=args.mode) as job:
        fit = job.fit()
        metrics = job.evaluate()
        res = job.shutdown()
    summary["logreg_he"] = {
        "loss_first": fit["history"][0]["loss"],
        "loss_last": fit["history"][-1]["loss"],
        **metrics,
        "arbiter_decryptions": res["arbiter"]["decrypted_values"],
        "comm": res["master"]["comm"],
    }

    (OUT / "demo_summary.json").write_text(json.dumps(summary, indent=1))
    for k, v in summary.items():
        extra = f" | AUC {v['auc']:.3f}" if "auc" in v else ""
        extra += f" ndcg@5 {v['ndcg@5']:.3f}" if "ndcg@5" in v else ""
        print(f"{k:10s} loss {v['loss_first']:.4f} -> {v['loss_last']:.4f}"
              f" | {v['comm']['sent_bytes']:,} B sent{extra}")
    print(f"written: {OUT}/demo_summary.json")


if __name__ == "__main__":
    main()
