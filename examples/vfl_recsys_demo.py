"""The paper's §4 demo, end to end: SBOL-like master silo + MegaMarket-
like member silo, arbiterless (linreg / split-NN) and arbitered
(Paillier-HE logreg) experiments, with the paper's logging (payload
bytes, exchange time, ML metrics) written to benchmarks/results/demo/.

  PYTHONPATH=src python examples/vfl_recsys_demo.py [--full]

--full uses the published SBOL scale (190k users); default is a reduced
scale so the demo finishes in seconds on CPU.
"""
import argparse
import json
import pathlib

import numpy as np

from repro.configs.vfl_recsys import VFLRecsysConfig
from repro.core.party import run_vfl
from repro.core.protocols.base import MasterData, MemberData, VFLConfig
from repro.core.protocols.base import _select
from repro.core.protocols.split_nn import mlp_apply
from repro.data.synthetic import make_recsys_silos
from repro.train.evals import recsys_report
from repro.train.metrics import MetricsLogger

OUT = pathlib.Path(__file__).resolve().parents[1] \
    / "benchmarks" / "results" / "demo"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--mode", default="thread",
                    choices=("thread", "process", "socket"))
    args = ap.parse_args()

    dcfg = VFLRecsysConfig() if args.full else VFLRecsysConfig().reduced()
    data = make_recsys_silos(dcfg, seed=0)
    master = MasterData(data.ids, data.labels.astype(np.float64),
                        data.features)
    members = [MemberData(ids, x) for ids, x in
               zip(data.member_ids, data.member_features)]
    OUT.mkdir(parents=True, exist_ok=True)
    summary = {}

    # 1. arbiterless VFL linear regression on implicit labels
    cfg = VFLConfig(protocol="linreg", epochs=4, batch_size=128, lr=0.05,
                    seed=0, use_psi=False)
    res = run_vfl(cfg, master, members, mode=args.mode)
    summary["linreg"] = {
        "loss_first": res["master"]["history"][0]["loss"],
        "loss_last": res["master"]["history"][-1]["loss"],
        "comm": res["master"]["comm"],
    }

    # 2. split-NN recommender (the paper's demo model family)
    cfg = VFLConfig(protocol="split_nn", epochs=30, batch_size=128, lr=0.3,
                    seed=0, use_psi=True, embedding_dim=dcfg.embedding_dim,
                    hidden=tuple(dcfg.bottom_dims[-1:]))
    res = run_vfl(cfg, master, members, mode=args.mode)
    # rank-quality report: compose the trained split model over the
    # matched users (the evaluator holds all silos; parties never did)
    order = res["master"]["order"]
    u = mlp_apply(res["master"]["bottom"],
                  _select(master.ids, order, master.x), final_act=True)
    for j, m in enumerate(members):
        u = u + mlp_apply(res[f"member{j}"]["params"],
                          _select(m.ids, order, m.x), final_act=True)
    scores = np.asarray(mlp_apply(res["master"]["top"], u))
    labels = _select(master.ids, order, np.asarray(master.y))
    report = recsys_report(scores, labels, k=5)
    summary["split_nn"] = {
        "loss_first": res["master"]["history"][0]["loss"],
        "loss_last": res["master"]["history"][-1]["loss"],
        "n_common": res["master"]["n_common"],
        **report,
        "comm": res["master"]["comm"],
    }

    # 3. arbitered HE logreg on product 0 (binary)
    yb = master.y[:, :1]
    cfg = VFLConfig(protocol="logreg_he", epochs=1, batch_size=32, lr=0.5,
                    seed=0, use_psi=False, he_bits=256)
    res = run_vfl(cfg, MasterData(master.ids, yb, master.x), members,
                  mode=args.mode)
    summary["logreg_he"] = {
        "loss_first": res["master"]["history"][0]["loss"],
        "loss_last": res["master"]["history"][-1]["loss"],
        "arbiter_decryptions": res["arbiter"]["decrypted_values"],
        "comm": res["master"]["comm"],
    }

    (OUT / "demo_summary.json").write_text(json.dumps(summary, indent=1))
    for k, v in summary.items():
        extra = f" | AUC {v['auc']:.3f} ndcg@5 {v['ndcg@5']:.3f}" \
            if "auc" in v else ""
        print(f"{k:10s} loss {v['loss_first']:.4f} -> {v['loss_last']:.4f}"
              f" | {v['comm']['sent_bytes']:,} B sent{extra}")
    print(f"written: {OUT}/demo_summary.json")


if __name__ == "__main__":
    main()
